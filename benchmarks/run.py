"""Benchmark harness: one section per paper table/figure (+ roofline).

Prints ``name,us_per_call,derived`` CSV.  ``derived`` is the
table-specific metric (accuracy for Tables/Figs, bits-per-param for the
comm table, useful-compute ratio for the roofline).

The ``engine``/``kernels``/``scale``/``service`` sections additionally write
machine-readable results (per-engine rates + config + commit) to
``BENCH_<name>.json`` at the repo root, so the bench trajectory is
tracked across commits instead of living only in stdout.  On every
invocation the harness checks the tracked BENCH files' recorded commits
against HEAD and warns about any that is NOT an ancestor (i.e. the
numbers predate a rebase/amend and no longer belong to this history).

Usage:  PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]
"""
import argparse
import glob
import json
import os
import subprocess
import sys


def _warn_stale_bench_files() -> None:
    """Warn when a BENCH_*.json records a commit that is not an ancestor
    of HEAD — its numbers were produced on a line of history this
    checkout does not contain (rebase/amend), so the bench trajectory
    has a hole until the section is re-run."""
    root = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
    for path in sorted(glob.glob(os.path.join(root, "BENCH_*.json"))):
        try:
            commit = json.load(open(path)).get("commit", "unknown")
        except (OSError, ValueError):
            continue
        if commit == "unknown":
            continue
        try:
            ok = subprocess.run(
                ["git", "merge-base", "--is-ancestor", commit, "HEAD"],
                cwd=root, capture_output=True).returncode == 0
        except OSError:       # no git binary
            return
        if not ok:
            print(f"# WARNING: {os.path.basename(path)} was recorded at "
                  f"{commit[:12]}, which is not an ancestor of HEAD — "
                  f"re-run its section to refresh it", file=sys.stderr)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer rounds / smaller populations (CI mode)")
    ap.add_argument("--only", default=None,
                    help="table1|fig4|fig5|fig6|comm|engine|kernels|"
                         "scale|service|privacy|roofline")
    args = ap.parse_args()

    _warn_stale_bench_files()

    from . import (engine_bench, fl_suite, kernel_bench, privacy_bench,
                   roofline_report, scale_bench, service_bench)

    rounds = 6 if args.quick else 15
    sections = {
        "table1": lambda: fl_suite.table1_accuracy(rounds=rounds),
        "fig4": lambda: fl_suite.fig4_ablation(rounds=rounds),
        "fig5": lambda: fl_suite.fig5_noise(rounds=max(4, rounds - 3)),
        "fig6": fl_suite.fig6_complexity,
        "comm": fl_suite.comm_table,
        "engine": lambda: (
            engine_bench.engine_rows(n_rounds=10 if args.quick else 30)
            + engine_bench.sweep_rows(n_rounds=5 if args.quick else 10,
                                      n_seeds=8 if args.quick else 32)
            + engine_bench.wire_rows(n_rounds=5 if args.quick else 20)),
        "kernels": lambda: kernel_bench.kernel_rows(smoke=args.quick),
        "scale": lambda: scale_bench.scale_rows(quick=args.quick),
        "service": lambda: service_bench.service_rows(quick=args.quick),
        "privacy": lambda: privacy_bench.privacy_rows(quick=args.quick),
        "roofline": roofline_report.roofline_rows,
    }
    if args.only:
        sections = {args.only: sections[args.only]}

    print("name,us_per_call,derived")
    for name, fn in sections.items():
        try:
            rows = fn()
            for row in rows:
                print(f"{row['name']},{row['us_per_call']:.1f},"
                      f"{row['derived']}")
                sys.stdout.flush()
            if name == "engine":
                path = engine_bench.write_bench_json(
                    rows, n_rounds=10 if args.quick else 30,
                    n_sweep_seeds=8 if args.quick else 32)
                print(f"# wrote {path}", file=sys.stderr)
            elif name == "kernels":
                path = kernel_bench.write_bench_json(rows,
                                                     smoke=args.quick)
                print(f"# wrote {path}", file=sys.stderr)
            elif name == "scale":
                path = scale_bench.write_bench_json(rows,
                                                    quick=args.quick)
                print(f"# wrote {path}", file=sys.stderr)
            elif name == "service":
                path = service_bench.write_bench_json(rows,
                                                      quick=args.quick)
                print(f"# wrote {path}", file=sys.stderr)
            elif name == "privacy":
                path = privacy_bench.write_bench_json(rows,
                                                      quick=args.quick)
                print(f"# wrote {path}", file=sys.stderr)
        except Exception as e:  # noqa: BLE001
            print(f"{name}/ERROR,0.0,{type(e).__name__}")


if __name__ == "__main__":
    main()
