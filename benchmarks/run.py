"""Benchmark harness: one section per paper table/figure (+ roofline).

Prints ``name,us_per_call,derived`` CSV.  ``derived`` is the
table-specific metric (accuracy for Tables/Figs, bits-per-param for the
comm table, useful-compute ratio for the roofline).

The ``engine`` section additionally writes machine-readable results
(rounds/sec per engine + config + commit) to ``BENCH_engine.json`` at the
repo root, so the bench trajectory is tracked across commits instead of
living only in stdout.

Usage:  PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]
"""
import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer rounds (CI mode)")
    ap.add_argument("--only", default=None,
                    help="table1|fig4|fig5|fig6|comm|engine|kernels|"
                         "roofline")
    args = ap.parse_args()

    from . import engine_bench, fl_suite, kernel_bench, roofline_report

    rounds = 6 if args.quick else 15
    sections = {
        "table1": lambda: fl_suite.table1_accuracy(rounds=rounds),
        "fig4": lambda: fl_suite.fig4_ablation(rounds=rounds),
        "fig5": lambda: fl_suite.fig5_noise(rounds=max(4, rounds - 3)),
        "fig6": fl_suite.fig6_complexity,
        "comm": fl_suite.comm_table,
        "engine": lambda: (
            engine_bench.engine_rows(n_rounds=10 if args.quick else 30)
            + engine_bench.sweep_rows(n_rounds=5 if args.quick else 10,
                                      n_seeds=8 if args.quick else 32)
            + engine_bench.wire_rows(n_rounds=5 if args.quick else 20)),
        "kernels": lambda: kernel_bench.kernel_rows(smoke=args.quick),
        "roofline": roofline_report.roofline_rows,
    }
    if args.only:
        sections = {args.only: sections[args.only]}

    print("name,us_per_call,derived")
    for name, fn in sections.items():
        try:
            rows = fn()
            for row in rows:
                print(f"{row['name']},{row['us_per_call']:.1f},"
                      f"{row['derived']}")
                sys.stdout.flush()
            if name == "engine":
                path = engine_bench.write_bench_json(
                    rows, n_rounds=10 if args.quick else 30,
                    n_sweep_seeds=8 if args.quick else 32)
                print(f"# wrote {path}", file=sys.stderr)
            elif name == "kernels":
                path = kernel_bench.write_bench_json(rows,
                                                     smoke=args.quick)
                print(f"# wrote {path}", file=sys.stderr)
        except Exception as e:  # noqa: BLE001
            print(f"{name}/ERROR,0.0,{type(e).__name__}")


if __name__ == "__main__":
    main()
