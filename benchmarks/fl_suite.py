"""Paper-table benchmarks (CPU-scale reproductions of Tables 1-2, Figs 3-6).

Each function mirrors one table/figure of the paper on the synthetic image
task; numbers land in EXPERIMENTS.md.  Scale: 10 clients / 5 per round /
reduced rounds — enough for the orderings the paper claims (FedMRN ≈
FedAvg ≫ sign-style ≫ model-compression baselines) to reproduce.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.data import (make_federated_dataset, make_image_task,
                        make_partition, sample_local_batches)
from repro.fed import Experiment, ExperimentSpec, FLConfig, get_algorithm
from repro.models.cnn import cnn_apply, cnn_init, cnn_loss


def _setup(partition: str, seed: int = 0):
    task = make_image_task(seed, n=3000, hw=16, n_classes=8, noise=0.5)
    n_test = 600
    xtr, ytr = task.x[:-n_test], task.y[:-n_test]
    xte = jnp.asarray(task.x[-n_test:])
    yte = jnp.asarray(task.y[-n_test:])
    parts = make_partition(partition, seed, ytr, num_clients=10)
    params = cnn_init(jax.random.key(seed), n_classes=8, channels=(8, 16))
    return xtr, ytr, xte, yte, parts, params


def _run(algo: str, partition: str, rounds: int = 15, seed: int = 0,
         engine: str = "scan", **cfg_kw) -> Dict:
    get_algorithm(algo)          # fail fast on names not in the registry
    xtr, ytr, xte, yte, parts, params = _setup(partition, seed)
    cfg = FLConfig(algorithm=algo, num_clients=10, clients_per_round=5,
                   rounds=rounds, local_steps=10, batch_size=32, lr=0.1,
                   seed=seed,
                   **{"noise_alpha": 0.025 if algo == "fedmrns" else 0.05,
                      **cfg_kw})
    ds = make_federated_dataset(xtr, ytr, parts, x_test=xte, y_test=yte,
                                batch_seed=seed * 131 + 1)
    spec = ExperimentSpec(loss_fn=cnn_loss, params=params, data=ds,
                          config=cfg, eval_apply=cnn_apply,
                          eval_every=max(1, rounds // 4))
    # every table/figure runs as one fused scan program by default;
    # engine="batched"/"looped" reproduce the per-round / per-client models
    return Experiment(spec).run(engine=engine).to_history()


def table1_accuracy(partitions=("iid", "noniid2"), rounds=15):
    """Table 1/2: accuracy of all methods across data distributions."""
    algos = ("fedavg", "fedmrn", "fedmrns", "signsgd", "terngrad", "topk",
             "drive", "eden", "fedpm", "fedsparsify")
    rows = []
    for part in partitions:
        for algo in algos:
            t0 = time.time()
            hist = _run(algo, part, rounds=rounds)
            rows.append(dict(
                name=f"table1/{part}/{algo}",
                us_per_call=(time.time() - t0) * 1e6 / rounds,
                derived=round(hist["final_acc"], 4)))
    return rows


def fig4_ablation(rounds=15):
    """Fig 4: PSM ablations + post-training-SM comparison."""
    variants = [
        ("fedmrn", {}),                                    # full PSM
        ("fedmrn_wo_pm", {"use_pm": False}),
        ("fedmrn_wo_sm", {"use_sm": False}),
        ("fedmrn_wo_psm", {"use_sm": False, "use_pm": False}),
        ("fedavg_w_sm", {}),                               # post-train SM
        ("signsgd", {}),
    ]
    rows = []
    for name, kw in variants:
        algo = ("post_sm" if name == "fedavg_w_sm"
                else "signsgd" if name == "signsgd" else "fedmrn")
        t0 = time.time()
        hist = _run(algo, "noniid2", rounds=rounds, **kw)
        rows.append(dict(name=f"fig4/{name}",
                         us_per_call=(time.time() - t0) * 1e6 / rounds,
                         derived=round(hist["final_acc"], 4)))
    return rows


def fig5_noise(rounds=12):
    """Fig 5: noise distribution × magnitude sweep."""
    rows = []
    for dist in ("uniform", "gauss", "bernoulli"):
        for alpha in (0.0125, 0.025, 0.05, 0.1):
            t0 = time.time()
            hist = _run("fedmrn", "noniid2", rounds=rounds,
                        noise_dist=dist, noise_alpha=alpha)
            rows.append(dict(
                name=f"fig5/{dist}/a{alpha}",
                us_per_call=(time.time() - t0) * 1e6 / rounds,
                derived=round(hist["final_acc"], 4)))
    return rows


def fig6_complexity():
    """Fig 6: local-training wall time + update-compression wall time."""
    xtr, ytr, xte, yte, parts, params = _setup("iid")
    from repro.core import (FedMRNConfig, NoiseConfig, client_local_update,
                            make_compressor, sgd_local_update)
    batches = sample_local_batches(0, xtr, ytr, parts[0], steps=10,
                                   batch=32)
    rows = []

    def timed(fn, n=5):
        fn()  # compile
        t0 = time.time()
        for _ in range(n):
            jax.block_until_ready(fn())
        return (time.time() - t0) / n

    mrn_cfg = FedMRNConfig(noise=NoiseConfig(alpha=0.05), lr=0.1)
    t_mrn = timed(lambda: client_local_update(
        cnn_loss, params, batches, cfg=mrn_cfg, base_seed=0, round_idx=0,
        client_id=0, train_key=jax.random.key(1)).losses)
    rows.append(dict(name="fig6/train/fedmrn", us_per_call=t_mrn * 1e6,
                     derived=0))
    t_avg = timed(lambda: sgd_local_update(cnn_loss, params, batches,
                                           lr=0.1)[1])
    rows.append(dict(name="fig6/train/fedavg", us_per_call=t_avg * 1e6,
                     derived=round(t_mrn / t_avg, 3)))
    u, _ = sgd_local_update(cnn_loss, params, batches, lr=0.1)
    for comp in ("signsgd", "terngrad", "topk", "drive", "eden"):
        c = make_compressor(comp)
        t = timed(lambda: c(u, jax.random.key(2)))
        rows.append(dict(name=f"fig6/compress/{comp}", us_per_call=t * 1e6,
                         derived=round(t / t_avg, 4)))
    return rows


def comm_table():
    """Uplink cost accounting (paper §5.1.3 bit model, exact + paper-style)."""
    from repro.core import baseline_record, fedmrn_record, tree_num_params
    params = cnn_init(jax.random.key(0), n_classes=8, channels=(8, 16))
    P = tree_num_params(params)
    L = len(jax.tree_util.tree_leaves(params))
    rows = [dict(name="comm/fedmrn",
                 us_per_call=0.0,
                 derived=round(fedmrn_record(P).uplink_bpp, 4))]
    for m in ("fedavg", "signsgd", "terngrad", "topk", "qsgd", "eden"):
        rec = baseline_record(m, P, L)
        rows.append(dict(name=f"comm/{m}", us_per_call=0.0,
                         derived=round(rec.uplink_bpp, 4)))
    return rows
