"""Mask-uplink kernel microbenchmark: fused vs staged pipeline.

The client hot loop used to run the uplink as three separately dispatched
programs — PSM sample (f32 mask tree), bitpack (uint32 words), and the
server-side popcount (words → int8 bits → counts, a 32× re-expansion).
``mask_uplink_fused`` does all three in one pass, emitting packed words
and per-block count/weighted-sum partials directly, so the f32 mask tree
and the unpacked bit tensor never round-trip through HBM.

Rows (derived = calls/sec unless stated):
  kernels/uplink/<mode>/staged    sample → pack → unpack-counts (+ the
                                  Σ_k w_k n_k⊙m_k aggregate) as separate
                                  jitted dispatches, as the legacy route
                                  runs them
  kernels/uplink/<mode>/fused     one ``mask_uplink_fused`` program on
                                  the DEFAULT backend (pallas on TPU,
                                  the jnp oracle elsewhere)
  kernels/uplink/<mode>/speedup   staged/fused wall-time ratio — the
                                  acceptance row (>= 1.3x)
  kernels/apply/staged            server update as unpack-counts then
                                  ``w + n*(s*c)`` (two dispatches)
  kernels/apply/fused             one ``unpack_counts_apply`` program
  kernels/apply/speedup           staged/fused ratio

Analytic roofline rows (derived = bytes; the memory term of the
three-term roofline model, counting HBM traffic of each pipeline):
  kernels/roofline/<mode>/hbm_staged_B
  kernels/roofline/<mode>/hbm_fused_B
  kernels/roofline/<mode>/hbm_ratio   staged/fused — the memory-term
                                      delta the fusion buys

``write_bench_json`` emits the machine-readable ``BENCH_kernels.json``
next to the repo root (same trajectory-tracking idiom as
``BENCH_engine.json``).
"""
from __future__ import annotations

import json
import os
import subprocess
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.backend import pallas_interpret, resolve_backend
from repro.core.packing import pack_rows, unpack_rows
from repro.kernels.mask_uplink import ops as mops

# full sizes: K clients x 1M params — the regime of the paper's CNN;
# smoke mode (CI) shrinks P so the whole section runs in seconds.
K_FULL, P_FULL = 8, 1 << 20
K_SMOKE, P_SMOKE = 4, 1 << 16

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_kernels.json")

_EPS = 1e-30


def _time_calls(call, repeats: int = 3, n: int = 5) -> float:
    """Best-of-``repeats`` wall-seconds per call after a compile/warmup
    call (same idiom as engine_bench._time_rounds)."""
    jax.block_until_ready(call())
    best = float("inf")
    for _ in range(repeats):
        t0 = time.time()
        out = None
        for _ in range(n):
            out = call()
        jax.block_until_ready(out)
        best = min(best, (time.time() - t0) / n)
    return best


def _operands(K: int, P: int):
    ku, kn, kr = jax.random.split(jax.random.key(0), 3)
    u = 0.01 * jax.random.normal(ku, (K, P))
    n = 0.01 * jax.random.normal(kn, (K, P))
    r = jax.random.uniform(kr, (K, P))
    w = jnp.linspace(0.5, 1.5, K)
    return u, n, r, w


def _staged_fns(mode: str, P: int):
    """The legacy pipeline as separately jitted stages (each one is a
    real dispatch boundary in the legacy route: mask tree and bit tensor
    round-trip through HBM between them)."""

    @jax.jit
    def sample(u, n, r):
        safe = jnp.where(jnp.abs(n) < _EPS, _EPS, n)
        if mode == "signed":
            p = jnp.clip((u + n) / (2.0 * safe), 0.0, 1.0)
        else:
            p = jnp.clip(u / safe, 0.0, 1.0)
        return (r < p).astype(jnp.int8)

    pack = jax.jit(lambda m: pack_rows(m, backend="ref"))

    @jax.jit
    def counts(words):
        bits = unpack_rows(words, P, backend="ref")   # the 32x expansion
        return jnp.sum(bits, axis=0, dtype=jnp.int32)

    @jax.jit
    def wsum(w, n, m):
        if mode == "signed":
            hat = jnp.where(m.astype(bool), n, -n)
        else:
            hat = jnp.where(m.astype(bool), n, 0.0)
        return jnp.tensordot(w, hat, axes=1)

    return sample, pack, counts, wsum


def uplink_rows(K: int, P: int) -> List[Dict]:
    backend = resolve_backend(None)
    use_pallas = backend == "pallas"
    interp = pallas_interpret()
    u, n, r, w = _operands(K, P)
    rows = []
    for mode in ("binary", "signed"):
        sample, pack, counts, wsum = _staged_fns(mode, P)

        def staged():
            m = sample(u, n, r)
            words = pack(m)
            c = counts(words)
            s = wsum(w, n, m)
            return words, c, s

        fused_fn = jax.jit(lambda u, n, r, w: mops.mask_uplink_fused(
            u, n, r, None, None, w, mode=mode, use_pallas=use_pallas,
            interpret=interp))

        def fused():
            return fused_fn(u, n, r, w)

        t_staged = _time_calls(staged)
        t_fused = _time_calls(fused)
        rows += [
            dict(name=f"kernels/uplink/{mode}/staged",
                 us_per_call=t_staged * 1e6,
                 derived=round(1.0 / t_staged, 2)),
            dict(name=f"kernels/uplink/{mode}/fused",
                 us_per_call=t_fused * 1e6,
                 derived=round(1.0 / t_fused, 2)),
            dict(name=f"kernels/uplink/{mode}/speedup", us_per_call=0.0,
                 derived=round(t_staged / t_fused, 2)),
        ] + _roofline_rows(mode, K, P)
    return rows


def _roofline_rows(mode: str, K: int, P: int) -> List[Dict]:
    """Analytic HBM traffic (bytes) of each pipeline — the memory term
    of the roofline model.  Staged stages are separate programs, so
    every intermediate is an HBM round-trip; the fused kernel stages
    everything through VMEM and only the wire words + per-block partial
    sums ever hit HBM."""
    f32, i8, u32 = 4, 1, 4
    words_B = (P // 32 + (1 if P % 32 else 0)) * u32 * K
    # staged: sample(rd u,n,r; wr mask) + pack(rd mask; wr words)
    #       + counts(rd words; wr bits; rd bits; wr counts)
    #       + wsum(rd n, mask; wr hat is fused into the tensordot: rd only)
    staged = (3 * K * P * f32 + K * P * i8            # sample
              + K * P * i8 + words_B                  # pack
              + words_B + 2 * K * P * i8 + P * 4      # unpack + popcount
              + K * P * (f32 + i8) + P * f32)         # weighted aggregate
    # fused: rd u,n,r once; wr words + count/wsum partials (gr rows each)
    gr = max(1, -(-K // 8))                            # K/8 row blocks
    fused = 3 * K * P * f32 + words_B + 2 * gr * P * 4
    return [
        dict(name=f"kernels/roofline/{mode}/hbm_staged_B", us_per_call=0.0,
             derived=staged),
        dict(name=f"kernels/roofline/{mode}/hbm_fused_B", us_per_call=0.0,
             derived=fused),
        dict(name=f"kernels/roofline/{mode}/hbm_ratio", us_per_call=0.0,
             derived=round(staged / fused, 2)),
    ]


def apply_rows(K: int, P: int) -> List[Dict]:
    """Server side: words → counts → global-model update."""
    backend = resolve_backend(None)
    use_pallas = backend == "pallas"
    interp = pallas_interpret()
    u, n, r, _ = _operands(K, P)
    m = (r < jnp.clip(u / jnp.where(jnp.abs(n) < _EPS, _EPS, n), 0, 1))
    words = jax.jit(lambda m: pack_rows(m.astype(jnp.int8),
                                        backend="ref"))(m)
    base = jnp.zeros((P,))
    scale = 1.0 / K

    unpack = jax.jit(lambda ws: jnp.sum(
        unpack_rows(ws, P, backend="ref"), axis=0, dtype=jnp.int32))
    apply_ = jax.jit(lambda c: base + n[0] * (scale * c.astype(jnp.float32)))

    def staged():
        return apply_(unpack(words))

    fused_fn = jax.jit(lambda ws: mops.unpack_counts_apply(
        ws, n[0], base, scale, 1.0, 0.0, use_pallas=use_pallas,
        interpret=interp))

    def fused():
        return fused_fn(words)

    t_staged = _time_calls(staged)
    t_fused = _time_calls(fused)
    return [
        dict(name="kernels/apply/staged", us_per_call=t_staged * 1e6,
             derived=round(1.0 / t_staged, 2)),
        dict(name="kernels/apply/fused", us_per_call=t_fused * 1e6,
             derived=round(1.0 / t_fused, 2)),
        dict(name="kernels/apply/speedup", us_per_call=0.0,
             derived=round(t_staged / t_fused, 2)),
    ]


def kernel_rows(smoke: bool = False) -> List[Dict]:
    K, P = (K_SMOKE, P_SMOKE) if smoke else (K_FULL, P_FULL)
    return uplink_rows(K, P) + apply_rows(K, P)


def write_bench_json(rows: List[Dict], path: str = BENCH_JSON,
                     smoke: bool = False) -> str:
    """Emit machine-readable kernel results (bench trajectory idiom)."""
    try:
        commit = subprocess.check_output(
            ["git", "rev-parse", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            text=True).strip()
    except Exception:  # noqa: BLE001 — no git in CI tarballs
        commit = "unknown"
    K, P = (K_SMOKE, P_SMOKE) if smoke else (K_FULL, P_FULL)
    results: Dict[str, Dict] = {}
    for r in rows:
        if r["name"].startswith("kernels/"):
            key = "/".join(r["name"].split("/")[1:-1])
            results.setdefault(key, {})[r["name"].split("/")[-1]] = (
                r["derived"])
    doc = {
        "bench": "kernels",
        "commit": commit,
        "config": {"clients": K, "params": P, "smoke": smoke,
                   "backend": resolve_backend(None),
                   "n_devices": jax.local_device_count(),
                   "unit": "calls_per_sec (speedup/hbm_ratio rows are "
                           "ratios; hbm_*_B rows are analytic bytes)"},
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "results": results,
    }
    path = os.path.abspath(path)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
    return path


if __name__ == "__main__":
    import sys
    smoke = "--smoke" in sys.argv
    print("name,us_per_call,derived")
    all_rows = kernel_rows(smoke=smoke)
    for row in all_rows:
        print(f"{row['name']},{row['us_per_call']:.1f},{row['derived']}")
    print(f"# wrote {write_bench_json(all_rows, smoke=smoke)}",
          file=sys.stderr)
