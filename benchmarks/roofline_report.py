"""§Roofline benchmark: read dry-run records → three-term table rows."""
from __future__ import annotations

import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def roofline_rows():
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    from repro.sharding.roofline import load_all
    rows = []
    if not os.path.isdir(DRYRUN_DIR):
        return [dict(name="roofline/missing", us_per_call=0.0, derived=0.0)]
    for rec, r in load_all(DRYRUN_DIR):
        dom_ms = {"compute": r.compute_s, "memory": r.memory_s,
                  "collective": r.collective_s}[r.dominant] * 1e3
        rows.append(dict(
            name=f"roofline/{r.arch}/{r.shape}/{r.mesh}/{r.dominant}",
            us_per_call=round(dom_ms * 1e3, 1),   # dominant term in us
            derived=round(r.useful_ratio, 4)))
    return rows
