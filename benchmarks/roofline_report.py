"""§Roofline benchmark: read dry-run records → three-term table rows.

Imported via ``PYTHONPATH=src python -m benchmarks.run`` like every
other section — ``repro`` must already be importable; there is no
``sys.path`` surgery here.

When there is nothing to report the section emits an explicit
``roofline/missing`` row whose ``derived`` column carries the REASON
(no dry-run directory, or an empty one), instead of a silent zero row
that is indistinguishable from a real measurement.
"""
from __future__ import annotations

import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def _missing(reason: str):
    return [dict(name="roofline/missing", us_per_call=0.0, derived=reason)]


def roofline_rows():
    from repro.sharding.roofline import load_all
    if not os.path.isdir(DRYRUN_DIR):
        return _missing(f"no dry-run dir at {os.path.abspath(DRYRUN_DIR)}; "
                        "run the sharding dry-run first")
    rows = []
    for rec, r in load_all(DRYRUN_DIR):
        dom_ms = {"compute": r.compute_s, "memory": r.memory_s,
                  "collective": r.collective_s}[r.dominant] * 1e3
        rows.append(dict(
            name=f"roofline/{r.arch}/{r.shape}/{r.mesh}/{r.dominant}",
            us_per_call=round(dom_ms * 1e3, 1),   # dominant term in us
            derived=round(r.useful_ratio, 4)))
    if not rows:
        return _missing(f"dry-run dir {os.path.abspath(DRYRUN_DIR)} "
                        "contains no records")
    return rows
